"""Unit tests for the multi-tenant SLO layer: ``SLOClassSet`` semantics,
``attainment_by_class`` edge cases, the per-class ``run_once`` columns,
and the min-over-classes goodput contract (one starved tenant caps the
frontier).
"""
import functools

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.request import Request
from repro.core.slo import (DATASET_SLOS, DEFAULT_SLO_CLASS, SLO,
                            SLOClassSet, as_slo_class_set, attainment,
                            attainment_by_class, attainment_mixed)
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import goodput, run_once
from repro.simulator.scenarios import make_mixed_scenario

TIGHT = SLO(ttft=1.0, tpot=0.05)
LOOSE = SLO(ttft=30.0, tpot=1.0)


def _req(rid, cls, ttft=0.5, n_tokens=5, tpot=0.01, finished=True):
    """A finished request with the given realized TTFT/TPOT."""
    r = Request(rid=rid, arrival_time=0.0, prompt_len=10,
                output_len=n_tokens, slo_class=cls)
    r.first_token_time = ttft
    r.tokens_generated = n_tokens
    if n_tokens >= 2:
        r.second_token_time = ttft + tpot
    if finished:
        r.finish_time = ttft + tpot * max(0, n_tokens - 1)
    return r


# --------------------------------------------------------------------- #
# SLOClassSet semantics
# --------------------------------------------------------------------- #
def test_class_set_construction_and_lookup():
    cs = SLOClassSet.make({"a": TIGHT, "b": LOOSE}, default="b")
    assert cs.names == ("a", "b")
    assert not cs.is_single
    assert cs.default_slo == LOOSE
    assert cs.get("a") == TIGHT
    assert cs.get("nope") == LOOSE          # unknown tag -> default class
    assert cs.ttft == LOOSE.ttft and cs.tpot == LOOSE.tpot
    r = Request(rid=0, arrival_time=0.0, prompt_len=1, output_len=1,
                slo_class="a")
    assert cs.for_request(r) == TIGHT


def test_class_set_default_resolution():
    # DEFAULT_SLO_CLASS wins when present; else first sorted name
    cs = SLOClassSet.make({DEFAULT_SLO_CLASS: TIGHT, "z": LOOSE})
    assert cs.default == DEFAULT_SLO_CLASS
    cs2 = SLOClassSet.make({"m": TIGHT, "z": LOOSE})
    assert cs2.default == "m"


def test_class_set_rejects_bad_specs():
    with pytest.raises(ValueError):
        SLOClassSet((), "default")
    with pytest.raises(KeyError):
        SLOClassSet((("a", TIGHT),), "missing")


def test_as_slo_class_set_coercion():
    cs = as_slo_class_set(TIGHT)
    assert cs.is_single and cs.default_slo == TIGHT
    assert as_slo_class_set(cs) is cs


# --------------------------------------------------------------------- #
# attainment_by_class edge cases
# --------------------------------------------------------------------- #
CS = SLOClassSet.make({"a": TIGHT, "b": LOOSE}, default="a")


def test_empty_class_reports_zero():
    reqs = [_req(0, "a")]
    by = attainment_by_class(reqs, CS)
    assert set(by) == {"a", "b"}
    assert by["b"] == 0.0                   # no traffic: scalar convention
    assert by["a"] == 1.0


def test_class_with_only_unfinished_requests_reports_zero():
    reqs = [_req(0, "a"),
            _req(1, "b", finished=False)]
    by = attainment_by_class(reqs, CS)
    assert by == {"a": 1.0, "b": 0.0}


def test_single_token_requests_are_tpot_exempt():
    # one generated token: no decode stream exists, only TTFT counts
    ok = _req(0, "a", ttft=0.5, n_tokens=1)
    late = _req(1, "a", ttft=5.0, n_tokens=1)
    by = attainment_by_class([ok, late], CS)
    assert by["a"] == 0.5
    # a slow-decode multi-token request fails the same class's TPOT
    slow = _req(2, "a", ttft=0.5, n_tokens=10, tpot=1.0)
    assert attainment_by_class([ok, slow], CS)["a"] == 0.5


def test_unknown_tag_scored_under_default_class():
    stray = _req(0, "mystery", ttft=0.5)
    by = attainment_by_class([stray], CS)
    assert by["a"] == 1.0                   # bucketed into default 'a'
    assert by["b"] == 0.0


def test_single_class_agrees_with_scalar_attainment():
    single = SLOClassSet.single(TIGHT, name="only")
    reqs = [_req(i, "only", ttft=0.2 * i) for i in range(12)]
    by = attainment_by_class(reqs, single)
    assert list(by) == ["only"]
    assert by["only"] == attainment(reqs, TIGHT)
    assert attainment_mixed(reqs, single) == attainment(reqs, TIGHT)


def test_attainment_mixed_scores_each_request_against_its_class():
    reqs = [_req(0, "a", ttft=5.0),         # violates TIGHT
            _req(1, "b", ttft=5.0)]         # fine under LOOSE
    assert attainment_mixed(reqs, CS) == 0.5
    assert attainment_by_class(reqs, CS) == {"a": 0.0, "b": 1.0}


# --------------------------------------------------------------------- #
# constraint 2b under heterogeneous TPOT budgets
# --------------------------------------------------------------------- #
def test_admission_respects_running_decodes_tpot_floor():
    """A lax-TPOT admission must not slow the shared decode batch past a
    tight-TPOT tenant's budget: constraint 2b checks the projected decode
    iteration time against min(incoming class TPOT, decode_tpot_floor)."""
    from repro.core.constraints import check_constraints
    from repro.core.instance import InstanceStatus

    def status(floor):
        return InstanceStatus(
            iid=0, phase="decode", pending_prefill_lens=[],
            pending_prefill_tokens=0, num_decoding=3,
            saved_tpots=[10.0, 10.0, 10.0],     # ample slack: 2a passes
            kv_tokens_used=0, kv_tokens_capacity=10**6,
            last_switch_time=0.0,
            decode_iter_time_plus_one=0.06, decode_tpot_floor=floor)

    lax = SLO(ttft=30.0, tpot=0.5)
    req = Request(rid=0, arrival_time=0.0, prompt_len=10, output_len=5,
                  slo_class="lax")
    pred = lambda n: 1e-4 * n   # noqa: E731 — trivial prefill predictor
    # tight-class decodes running (floor 0.05 < projected 0.06): reject
    assert not check_constraints(status(0.05), req, lax, pred, now=0.0)
    # only lax decodes running (floor 0.5): the same admission is fine
    assert check_constraints(status(0.5), req, lax, pred, now=0.0)
    # single-class legacy form: default floor is +inf -> only slo.tpot
    assert check_constraints(status(float("inf")), req, lax, pred,
                             now=0.0)


# --------------------------------------------------------------------- #
# metrics integration: per-class columns + min-over-classes goodput
# --------------------------------------------------------------------- #
COST = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)
MIX = ("alpaca", "longbench")
MIX_SLOS = SLOClassSet.make({w: DATASET_SLOS[w] for w in MIX})


def test_run_once_emits_per_class_columns_for_mixed_slo():
    scen = make_mixed_scenario("poisson", MIX, 4.0, seed=0)
    m = run_once(functools.partial(make_system, "ecoserve", COST, 4,
                                   MIX_SLOS),
                 scen, 4.0, MIX_SLOS, duration=15.0, warmup=2.0)
    assert set(m["attainment_by_class"]) == set(MIX)
    assert m["attainment_min"] == min(m["attainment_by_class"].values())
    assert 0.0 <= m["attainment_min"] <= m["attainment"] <= 1.0


def test_run_once_single_class_has_no_per_class_columns():
    scen = make_mixed_scenario("poisson", ["sharegpt"], 4.0, seed=0)
    slo = SLOClassSet.single(DATASET_SLOS["sharegpt"], name="sharegpt")
    m = run_once(functools.partial(make_system, "vllm", COST, 4, slo),
                 scen, 4.0, slo, duration=10.0, warmup=2.0)
    assert "attainment_by_class" not in m
    assert "attainment_min" not in m


def test_attainment_min_ignores_classes_with_no_traffic():
    """A class that submitted nothing is vacuously fine (matching the
    single-class 'not submitted' convention) — the min-over-classes
    criterion must not zero a low-rate goodput probe just because one
    tenant drew no arrivals.  The per-class grid still reports 0.0 for
    the empty class (the scalar-attainment empty-set convention)."""
    class OneClassOnly:
        rate = 2.0

        def generate(self, duration):
            return [Request(rid=i, arrival_time=3.1 + 0.1 * i,
                            prompt_len=10, output_len=2,
                            slo_class="alpaca") for i in range(30)]

    m = run_once(functools.partial(make_system, "vllm", COST, 4, MIX_SLOS),
                 OneClassOnly(), 2.0, MIX_SLOS, duration=15.0, warmup=2.0)
    assert m["attainment_by_class"]["longbench"] == 0.0
    assert m["attainment_min"] == m["attainment_by_class"]["alpaca"]
    assert m["attainment_min"] > 0.0


def test_goodput_is_capped_by_the_starved_class():
    """The min-over-classes contract: a class whose SLO is unmeetable
    zeroes the frontier even though the aggregate attainment (the other
    class passes everything) would clear the target."""
    factory = functools.partial(make_mixed_scenario, "poisson", MIX)
    sys_factory = functools.partial(make_system, "vllm", COST, 4)
    impossible = SLOClassSet.make({"alpaca": SLO(ttft=1e-9, tpot=1e-9),
                                   "longbench": SLO(ttft=1e9, tpot=1e9)})
    g = goodput(functools.partial(sys_factory, impossible), factory,
                impossible, target_attainment=0.45,
                lo=0.5, hi=4.0, tol=0.5, duration=8.0)
    assert g["goodput"] == 0.0
    both_easy = SLOClassSet.make({"alpaca": SLO(ttft=1e9, tpot=1e9),
                                  "longbench": SLO(ttft=1e9, tpot=1e9)})
    g2 = goodput(functools.partial(sys_factory, both_easy), factory,
                 both_easy, target_attainment=0.45,
                 lo=0.5, hi=4.0, tol=0.5, duration=8.0)
    assert g2["goodput"] > 0.0
    assert set(g2["attainment_by_class"]) == set(MIX)
