"""Property layer for the flight recorder: tracing is observation-only.

The load-bearing invariant: a traced cell is bit-identical — on every
golden-visible key — to the untraced cell of the same spec.  The trace
axis must therefore be seed-neutral by construction, across strategies,
scenarios, rates, seeds, and the runner's 1/2/3-worker execution modes
(in-process vs spawned pools).

Runs under hypothesis when installed (``conftest.py`` pins the
derandomized ``repro-ci`` profile); otherwise the seeded fallback drives
the same checks over a fixed sample.
"""
import json

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import run_once
from repro.simulator.runner import ExperimentRunner, cell_seed
from repro.simulator.scenarios import make_scenario

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

STRATEGIES = ("ecoserve", "vllm", "distserve")
SCENARIOS = ("poisson", "bursty")


def _run(strategy, scenario, rate, seed, trace):
    cost = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20,
                             tp=4, pp=1)
    slo = DATASET_SLOS["sharegpt"]

    def factory():
        return make_system(strategy, cost, 2, slo)

    scen = make_scenario(scenario, "sharegpt", rate, seed=seed)
    return run_once(factory, scen, rate, slo, duration=8.0, warmup=1.5,
                    seed=seed, trace=trace)


def check_trace_is_seed_neutral(strategy, scenario, rate, seed):
    plain = _run(strategy, scenario, rate, seed, trace=None)
    traced = _run(strategy, scenario, rate, seed, trace=True)
    digest = traced.pop("trace")
    assert digest["events"] > 0
    # bit-identical on every remaining key — not approx-equal: the same
    # floats, the same structures (golden rows never see "trace")
    assert json.dumps(plain, sort_keys=True) \
        == json.dumps(traced, sort_keys=True)


@needs_hypothesis
def test_traced_equals_untraced_hypothesis():
    @given(strategy=st.sampled_from(STRATEGIES),
           scenario=st.sampled_from(SCENARIOS),
           rate=st.sampled_from((2.0, 4.0, 6.0)),
           seed=st.integers(min_value=0, max_value=2**20))
    @settings(max_examples=10)
    def run(strategy, scenario, rate, seed):
        check_trace_is_seed_neutral(strategy, scenario, rate, seed)
    run()


def test_traced_equals_untraced_seeded():
    """Fallback drive (also runs alongside hypothesis: it pins the
    golden-grid corner cells specifically)."""
    for strategy in STRATEGIES:
        for scenario in SCENARIOS:
            seed = cell_seed(42, strategy, scenario, 6.0)
            check_trace_is_seed_neutral(strategy, scenario, 6.0, seed)


def test_traced_cell_writes_jsonl_and_stays_neutral(tmp_path):
    path = tmp_path / "cell.trace.jsonl"
    plain = _run("ecoserve", "bursty", 6.0, 3, trace=None)
    traced = _run("ecoserve", "bursty", 6.0, 3, trace=str(path))
    digest = traced.pop("trace")
    assert digest["path"] == str(path) and path.exists()
    assert json.dumps(plain, sort_keys=True) \
        == json.dumps(traced, sort_keys=True)


@pytest.mark.parametrize("n_workers", [1, 2, 3])
def test_runner_trace_axis_is_worker_invariant(n_workers, tmp_path):
    """The traced grid matches the untraced grid bit-exactly on the
    metrics rows at every worker count, and the per-cell trace files are
    byte-identical across worker counts (the spawned pool replays the
    identical cells)."""
    def runner(trace):
        return ExperimentRunner(
            strategies=("ecoserve",), scenarios=("poisson", "bursty"),
            rates=(6.0,), model="llama-30b", hw="L20", tp=4, pp=1,
            n_instances=2, workload="sharegpt", duration=8.0, warmup=1.5,
            base_seed=42, n_workers=n_workers, trace=trace)

    tdir = tmp_path / f"w{n_workers}"
    traced = runner(str(tdir)).run()
    plain = runner(None).run()
    assert not traced.get("errors") and not plain.get("errors")

    def rows(res):
        return sorted(
            ((c["strategy"], c["scenario"], c["rate"]),
             json.dumps(c["metrics"], sort_keys=True))
            for c in res["cells"])

    # "trace" never enters SUMMARY_KEYS, so the metrics dicts must
    # match bit-exactly, not just approximately
    assert rows(traced) == rows(plain)
    # meta stays schema-stable: untraced runs don't grow a trace field
    assert "trace" not in plain["meta"]
    assert traced["meta"]["trace"] == str(tdir)
    written = sorted(tdir.glob("*.trace.jsonl"))
    assert len(written) == len(traced["cells"])


def test_trace_files_byte_identical_across_worker_counts(tmp_path):
    blobs = {}
    for n_workers in (1, 2, 3):
        tdir = tmp_path / f"w{n_workers}"
        res = ExperimentRunner(
            strategies=("ecoserve",), scenarios=("poisson", "bursty"),
            rates=(6.0,), model="llama-30b", hw="L20", tp=4, pp=1,
            n_instances=2, workload="sharegpt", duration=8.0, warmup=1.5,
            base_seed=42, n_workers=n_workers, trace=str(tdir)).run()
        assert not res.get("errors")
        blobs[n_workers] = {p.name: p.read_bytes()
                            for p in sorted(tdir.glob("*.trace.jsonl"))}
    assert blobs[1] == blobs[2] == blobs[3]
    assert blobs[1], "no trace files written"
