"""Property/unit tests for the autoscaling control plane
(``repro.control``): hysteresis, cooldowns, regret backoff, the signal
collector, and the actuator's provisioning contract.

The hysteresis contracts (ISSUE satellite):

* **no decision flapping under a constant-rate trace** — steady signals
  inside the band produce zero decisions; steady healthy signals
  produce monotone contraction to the floor and then silence (never an
  up); a forced shrink-fail-grow cycle backs off exponentially instead
  of repeating;
* **cooldown respected** — consecutive same-direction decisions are
  always at least the configured cooldown apart, in pure-signal drives
  and in a full end-to-end simulation.

Pure-signal drives feed the controller synthetic snapshots, so the
properties hold by construction of the decision logic, not by luck of
one workload.
"""
import random

import pytest

from repro.control import (ControllerConfig, ControlLoopHarness,
                           SignalCollector, TargetBandController,
                           ThresholdController, make_controller)
from repro.core.slo import SLO, SLOClassSet

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

CFG = ControllerConfig()


def _signals(t, att=0.96, queue=0.0, kv=0.1, rate=6.0, n=4):
    return {"t": t, "rate_ewma": rate, "queue_depth": queue,
            "kv_occupancy": kv, "attainment_window": att,
            "arrivals_total": 0.0, "n_instances": float(n)}


def drive(controller, signal_fn, n0, ticks, interval=2.0):
    """Feed synthetic per-tick signals; apply decisions instantly.
    Returns [(t, decision)] for the non-zero decisions."""
    n = n0
    out = []
    for i in range(1, ticks + 1):
        t = i * interval
        d = controller.decide(signal_fn(t, n), n)
        if d:
            out.append((t, d))
        n += d
    return out, n


# --------------------------------------------------------------------- #
# no flapping under constant-rate signals
# --------------------------------------------------------------------- #
def test_in_band_signals_produce_no_decisions():
    """Attainment inside [target, att_high) with a modest queue: the
    hysteresis dead-band holds the pool exactly where it is."""
    ctrl = TargetBandController()
    events, n = drive(ctrl, lambda t, n: _signals(t, att=0.94, queue=2.0),
                      n0=4, ticks=200)
    assert events == [] and n == 4


def test_steady_health_contracts_monotonically_then_stays():
    """A constant healthy trace shrinks the pool to the floor and never
    reverses — the no-flapping guarantee in its purest form."""
    ctrl = TargetBandController()
    events, n = drive(ctrl, lambda t, n: _signals(t, att=1.0, queue=0.0),
                      n0=8, ticks=400)
    assert n == CFG.min_instances
    assert all(d == -1 for _, d in events)
    times = [t for t, _ in events]
    gaps = [b - a for a, b in zip(times, times[1:])]
    assert all(g >= CFG.cooldown_down - 1e-9 for g in gaps)


def test_steady_overload_expands_monotonically_then_stays():
    ctrl = TargetBandController()
    events, n = drive(ctrl, lambda t, n: _signals(t, att=0.5, queue=50.0),
                      n0=2, ticks=400)
    assert n == CFG.max_instances
    assert all(d == +1 for _, d in events)
    times = [t for t, _ in events]
    assert all(b - a >= CFG.cooldown_up - 1e-9
               for a, b in zip(times, times[1:]))


def test_unknown_attainment_blocks_contraction():
    """No completions yet (attainment window None) must hold the pool —
    contraction requires positive evidence of health."""
    ctrl = TargetBandController()
    events, n = drive(ctrl, lambda t, n: _signals(t, att=None),
                      n0=4, ticks=100)
    assert events == [] and n == 4


def test_deep_queue_alone_is_not_overload_while_attainment_safe():
    """EcoServe keeps a working prefill backlog by design: queue depth
    above queue_high with attainment >= att_safe must not expand."""
    ctrl = TargetBandController()
    events, n = drive(
        ctrl, lambda t, n: _signals(t, att=0.99, queue=12.0 * n),
        n0=4, ticks=100)
    assert events == [] and n == 4


# --------------------------------------------------------------------- #
# regret backoff kills limit cycles
# --------------------------------------------------------------------- #
def _cycle_signals(t, n):
    """A load with no stable pool size in the band: healthy at >= 4
    instances (invites shrink), failing below 4 (forces growth)."""
    return _signals(t, att=1.0 if n >= 4 else 0.5)


def test_shrink_fail_grow_cycle_backs_off_exponentially():
    ctrl = TargetBandController()
    events, _ = drive(ctrl, _cycle_signals, n0=4, ticks=600)
    downs = [t for t, d in events if d == -1]
    assert len(downs) >= 3, "cycle should attempt several contractions"
    gaps = [b - a for a, b in zip(downs, downs[1:])]
    # each regretted contraction at least doubles the standoff until the
    # cap: gaps between successive downs are non-decreasing and the
    # last observed gap dominates the first by the backoff factor
    assert all(b >= a - 1e-9 for a, b in zip(gaps, gaps[1:])), gaps
    assert gaps[-1] >= 4 * gaps[0] - 1e-9, gaps
    # and the penalty is capped, so contraction never freezes entirely
    assert max(gaps) <= CFG.cooldown_down * CFG.regret_cap + \
        2 * CFG.interval + 1e-9


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=80, deadline=None)
    @given(att=st.one_of(st.none(),
                         st.floats(min_value=0.0, max_value=1.0)),
           queue=st.floats(min_value=0.0, max_value=200.0),
           kv=st.floats(min_value=0.0, max_value=1.0),
           n0=st.integers(2, 8))
    def test_constant_signals_never_flap_property(att, queue, kv, n0):
        """ANY constant signal vector yields a monotone decision
        sequence — direction reversals require the signals to move."""
        ctrl = TargetBandController()
        events, _ = drive(
            ctrl, lambda t, n: _signals(t, att=att, queue=queue, kv=kv),
            n0=n0, ticks=300)
        directions = {d for _, d in events}
        assert len(directions) <= 1, (att, queue, kv, events)


def test_constant_signals_never_flap_seeded():
    rng = random.Random(3)
    for _ in range(40):
        att = rng.choice([None, rng.random()])
        queue = rng.uniform(0, 200)
        kv = rng.random()
        ctrl = TargetBandController()
        events, _ = drive(
            ctrl, lambda t, n: _signals(t, att=att, queue=queue, kv=kv),
            n0=rng.randint(2, 8), ticks=300)
        assert len({d for _, d in events}) <= 1, (att, queue, kv)


# --------------------------------------------------------------------- #
# end-to-end: cooldowns and bounded reversals on a real constant-rate sim
# --------------------------------------------------------------------- #
def test_constant_rate_simulation_respects_cooldowns():
    from repro.baselines import make_system
    from repro.configs import get_config
    from repro.core.slo import DATASET_SLOS
    from repro.simulator.cost_model import GPU_L20, InstanceCostModel
    from repro.simulator.engine import SimulationEngine
    from repro.simulator.scenarios import make_scenario

    cost = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20,
                             tp=4)
    slo = DATASET_SLOS["sharegpt"]
    system = make_system("ecoserve", cost, 4, slo)
    engine = SimulationEngine(system)
    harness = ControlLoopHarness(system, engine,
                                 make_controller("band")).attach()
    scen = make_scenario("poisson", "sharegpt", 6.0, seed=11)
    engine.run(scen.generate(90.0), horizon=140.0)
    events = harness.timeline.events
    ups = [e.t_decision for e in events if e.action == "up"]
    downs = [e.t_decision for e in events if e.action == "down"]
    assert all(b - a >= CFG.cooldown_up - 1e-9
               for a, b in zip(ups, ups[1:]))
    assert all(b - a >= CFG.cooldown_down - 1e-9
               for a, b in zip(downs, downs[1:]))
    # constant-rate traffic: direction reversals are rare transients,
    # not a sustained oscillation
    reversals = sum(1 for a, b in zip(events, events[1:])
                    if a.action != b.action)
    assert reversals <= 3, [(e.action, round(e.t_decision, 1))
                            for e in events]


# --------------------------------------------------------------------- #
# signal collector
# --------------------------------------------------------------------- #
def _mk_collector(**kw):
    return SignalCollector(SLOClassSet.single(SLO(ttft=1.0, tpot=0.1)),
                           **kw)


def test_rate_ewma_tracks_and_decays():
    col = _mk_collector(ewma_tau=5.0)

    class R:
        pass

    for i in range(100):              # 10 req/s for 10 s
        col.on_arrival(R(), i * 0.1)
    near = col.rate_ewma(10.0)
    assert 6.0 < near < 12.0          # warm EWMA sits near the true rate
    assert col.rate_ewma(40.0) < 0.1  # and decays once arrivals stop


def test_attainment_window_needs_min_samples_and_slides():
    from repro.core.request import Request

    col = _mk_collector(window=10.0, min_samples=4)

    def finished(rid, t, ok):
        # meets the SLO iff ``ok``: TTFT 0.2 vs 5.0 against a 1.0 s
        # budget; TPOT 0.05 against 0.1 either way
        r = Request(rid=rid, arrival_time=t, prompt_len=8, output_len=2)
        r.first_token_time = t + (0.2 if ok else 5.0)
        r.finish_time = r.first_token_time + 0.05
        r.tokens_generated = 2
        return r

    done = [finished(i, float(i), i % 2 == 0) for i in range(3)]
    col.consume_finished(done, 6.0)
    assert col.attainment_window() is None      # below min_samples
    done = done + [finished(10 + i, 12.0 + i, True) for i in range(4)]
    col.consume_finished(done, 16.0)
    att = col.attainment_window()
    assert att is not None and 0.5 < att < 1.0  # healthy majority, not all
    # slide far enough that only the healthy tail remains in the window
    col.consume_finished(done, 22.0)
    assert col.attainment_window() == 1.0


# --------------------------------------------------------------------- #
# actuator: provisioning delay through a live engine
# --------------------------------------------------------------------- #
def test_scale_up_lands_after_provisioning_delay():
    from repro.baselines import make_system
    from repro.configs import get_config
    from repro.core.slo import DATASET_SLOS
    from repro.simulator.cost_model import GPU_L20, InstanceCostModel
    from repro.simulator.engine import SimulationEngine
    from repro.simulator.scenarios import make_scenario

    cost = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20,
                             tp=4)
    slo = DATASET_SLOS["sharegpt"]
    system = make_system("ecoserve", cost, 2, slo)
    engine = SimulationEngine(system)
    harness = ControlLoopHarness(
        system, engine, make_controller("band:min=2,max=6")).attach()
    scen = make_scenario("bursty", "sharegpt", 14.0, seed=3)
    engine.run(scen.generate(30.0), horizon=70.0)
    events = harness.timeline.events
    ups = [e for e in events if e.action == "up"]
    assert ups, "overload must trigger expansion"
    for e in ups:
        assert e.t_effective == pytest.approx(
            e.t_decision + CFG.provision_delay)
    # the pool physically grew only after the delay: trajectory points
    # between decision and effect still show the old live count
    tl = harness.timeline
    first = ups[0]
    before = [p for p in tl.trajectory
              if p["t"] <= first.t_decision + 1e-9]
    assert before and before[-1]["n"] == first.n_before
    assert tl.summary()["n_max"] <= 6


def test_make_controller_specs_and_errors():
    c = make_controller("band:max=12,delay=2.5,hold=4")
    assert c.config.max_instances == 12
    assert c.config.provision_delay == 2.5
    assert c.config.hold_down == 4
    assert isinstance(make_controller("threshold"), ThresholdController)
    assert make_controller(c) is c
    with pytest.raises(KeyError, match="unknown controller"):
        make_controller("pid")
    with pytest.raises(KeyError, match="option"):
        make_controller("band:warp=9")
    with pytest.raises(TypeError):
        make_controller(42)
