"""Cost-model calibration against the paper's own measurements (Table 3)."""
import pytest

from repro.configs import get_config
from repro.simulator.cost_model import GPU_A800, GPU_L20, InstanceCostModel


def _node_prefill_rate(cfg, hw, tp):
    cm = InstanceCostModel(cfg=cfg, hw=hw, tp=tp)
    instances_per_node = hw.devices_per_node // tp
    lens = [512] * 8
    t = cm.prefill_time(lens)
    return instances_per_node * sum(lens) / t


def test_table3_llama30b_l20():
    """Paper Table 3: Llama-30B on an 8x L20 node -> 6584.6 tok/s."""
    cfg = get_config("llama-30b")
    rate = _node_prefill_rate(cfg, GPU_L20, tp=4)
    assert 0.6 * 6584.6 < rate < 1.6 * 6584.6, rate


def test_table3_llama30b_a800():
    """Paper Table 3: Llama-30B on an 8x A800 node -> 26189.2 tok/s."""
    cfg = get_config("llama-30b")
    rate = _node_prefill_rate(cfg, GPU_A800, tp=2)
    assert 0.6 * 26189.2 < rate < 1.6 * 26189.2, rate


def test_table3_kv_bandwidth_llama30b():
    """Paper: Llama-30B MHA KV ~1.52 MB/token => ~9.8 GB/s at 6584 tok/s."""
    cfg = get_config("llama-30b")
    cm = InstanceCostModel(cfg=cfg, hw=GPU_L20, tp=4)
    per_tok = cfg.kv_bytes_per_token(2)
    assert 1.2e6 < per_tok < 1.9e6         # ~1.52 MB in the paper
    bw = per_tok * 6584.6
    assert 7e9 < bw < 13e9                 # ~9.796 GB/s in Table 3


def test_table3_kv_bandwidth_codellama_gqa():
    """GQA compresses CodeLlama-34B KV: ~1.25 GB/s at 6838 tok/s."""
    cfg = get_config("codellama2-34b")
    per_tok = cfg.kv_bytes_per_token(2)
    bw = per_tok * 6838.92
    assert 0.8e9 < bw < 2.0e9              # ~1.25 GB/s in Table 3


def test_decode_is_memory_bound_and_prefill_compute_bound():
    cfg = get_config("llama-30b")
    cm = InstanceCostModel(cfg=cfg, hw=GPU_L20, tp=4)
    # decode: one iteration at batch 128 ~ memory bound; per-token time
    # must be far above the pure-compute time
    t_dec = cm.decode_time(128, [500] * 128)
    flops = 2.0 * cfg.param_count() * 128
    t_flops = flops / (GPU_L20.flops * 4)
    assert t_dec > 2 * t_flops
    # prefill of a long prompt is compute bound: halving compute speed
    # should ~double the time
    import dataclasses
    slow = dataclasses.replace(GPU_L20, flops=GPU_L20.flops / 2)
    t_fast = cm.prefill_time([2048])
    t_slow = InstanceCostModel(cfg=cfg, hw=slow, tp=4).prefill_time([2048])
    assert 1.7 < t_slow / t_fast < 2.3


def test_pp_decode_slower_than_tp_at_same_devices():
    """Fig. 11 premise: PP hurts single-batch decode latency."""
    cfg = get_config("codellama2-34b")
    tp4 = InstanceCostModel(cfg=cfg, hw=GPU_L20, tp=4, pp=1)
    pp2 = InstanceCostModel(cfg=cfg, hw=GPU_L20, tp=2, pp=2)
    assert pp2.decode_time(64, [500] * 64) > tp4.decode_time(64, [500] * 64)
    # ...but PP cuts TP communication for prefill throughput
    assert pp2._tp_comm_time(4096) < tp4._tp_comm_time(4096)
