"""Deterministic regression layer for the closed-loop autoscaling stack.

``tests/golden/dynamic_scaling.json`` pins the full dynamic-scaling grid
bit-exactly — per-phase attainment AND the recorded scaling timeline
(every decision time, direction, and pool size) for EcoServe under the
load-shifting shapes and both converted real-trace excerpts, each run
static / closed-loop (band) / threshold-ablation over identical
arrivals.  Regenerate (after an *intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_scaling_dynamic --write-golden
"""
import json
import pathlib

import pytest

from repro.simulator.runner import ExperimentRunner, dynamic_scaling_runner

GOLDEN = pathlib.Path(__file__).parent / "golden" / "dynamic_scaling.json"

CONVERTED_TRACES = ("trace:azure", "trace:burstgpt")


def _grid():
    return ExperimentRunner.grid(ExperimentRunner.load(GOLDEN))


def _rate():
    return ExperimentRunner.load(GOLDEN)["meta"]["rates"][0]


# --------------------------------------------------------------------- #
# golden reproduction (the worker pool is part of what's under test:
# cells must land identically regardless of scheduling order)
# --------------------------------------------------------------------- #
def test_dynamic_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(GOLDEN)
    fresh = dynamic_scaling_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "dynamic-scaling grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "dynamic-scaling grid no longer reproduces the golden metrics "
        "(per-phase attainment or the scaling timeline moved); if "
        "intentional, regenerate with `python -m benchmarks."
        "bench_scaling_dynamic --write-golden` and review the diff")


def test_dynamic_golden_covers_the_axes():
    golden = ExperimentRunner.load(GOLDEN)
    scenarios = {c["scenario"] for c in golden["cells"]}
    controllers = {c["autoscale"] for c in golden["cells"]}
    assert set(CONVERTED_TRACES) <= scenarios
    assert {"bursty", "diurnal", "ramp"} <= scenarios
    assert controllers == {None, "band", "threshold"}
    # static and autoscaled cells share seeds on purpose: identical
    # arrivals, so attainment deltas isolate the controller
    by_key = {}
    for c in golden["cells"]:
        by_key.setdefault(c["scenario"], set()).add(c["seed"])
    for scen, seeds in by_key.items():
        assert len(seeds) == 1, (scen, seeds)


# --------------------------------------------------------------------- #
# the headline claims, pinned in the golden so they cannot silently rot
# --------------------------------------------------------------------- #
def test_closed_loop_beats_static_on_bursty_and_converted_traces():
    """ISSUE acceptance: on the bursty and converted-trace scenarios the
    closed-loop controller achieves strictly higher min-over-phases
    attainment than the static 4-instance baseline."""
    grid, rate = _grid(), _rate()
    for scen in ("bursty",) + CONVERTED_TRACES:
        static = grid["ecoserve"][scen]["static"][rate]
        band = grid["ecoserve"][scen]["band"][rate]
        assert band["attainment_phase_min"] > \
            static["attainment_phase_min"], (
                scen, band["attainment_phase_min"],
                static["attainment_phase_min"])


def test_attainment_dips_then_recovers_under_load_shifts():
    """The Fig. 10 shape: under the closed loop, a load shift dips some
    phase's attainment below the steady level and a later phase recovers
    (the controller answered the shift) — while the static pool's dip
    has no recovery story on at least one shape (min phase is terminal
    or attainment stays collapsed)."""
    grid, rate = _grid(), _rate()
    recovered = 0
    for scen in ("bursty", "diurnal", "ramp") + CONVERTED_TRACES:
        phases = grid["ecoserve"][scen]["band"][rate][
            "attainment_by_phase"]
        dip = min(range(len(phases)), key=phases.__getitem__)
        if dip + 1 < len(phases) and \
                phases[dip + 1] > phases[dip] + 0.01:
            recovered += 1
    assert recovered >= 3, \
        f"expected post-dip recovery on most shapes, saw {recovered}"
    # the static diurnal cell collapses outright (its worst phase sits
    # near zero) — that is the gap the control plane exists to close
    static_diurnal = grid["ecoserve"]["diurnal"]["static"][rate]
    band_diurnal = grid["ecoserve"]["diurnal"]["band"][rate]
    assert static_diurnal["attainment_phase_min"] < 0.1
    assert band_diurnal["attainment_phase_min"] > 0.9


def test_timelines_respect_controller_contract():
    """Every recorded scale-up lands after the modeled provisioning
    delay; pool sizes stay inside the configured bounds; the static
    cells carry no timeline at all."""
    golden = ExperimentRunner.load(GOLDEN)
    from repro.control import ControllerConfig
    cfg = ControllerConfig()
    for cell in golden["cells"]:
        m = cell["metrics"]
        if cell["autoscale"] is None:
            assert "timeline" not in m
            continue
        tl = m["timeline"]
        assert tl["trajectory"], cell["scenario"]
        for p in tl["trajectory"]:
            assert cfg.min_instances <= p["n"] <= cfg.max_instances
            assert p["n"] <= p["n_target"] <= cfg.max_instances
        for e in tl["events"]:
            if e["action"] == "up":
                assert e["t_effective"] == pytest.approx(
                    e["t_decision"] + cfg.provision_delay)
            else:
                assert e["t_effective"] == e["t_decision"]
        if cell["autoscale"] == "band":   # threshold has no cooldowns
            ups = [e["t_decision"] for e in tl["events"]
                   if e["action"] == "up"]
            assert all(b - a >= cfg.cooldown_up - 1e-9
                       for a, b in zip(ups, ups[1:])), cell["scenario"]


def test_phase_columns_are_consistent():
    golden = ExperimentRunner.load(GOLDEN)
    n_phases = golden["meta"]["phases"]
    for cell in golden["cells"]:
        m = cell["metrics"]
        assert len(m["attainment_by_phase"]) == n_phases
        assert m["attainment_phase_min"] == min(m["attainment_by_phase"])


# --------------------------------------------------------------------- #
# trace scenario kinds through the runner plumbing
# --------------------------------------------------------------------- #
def test_trace_scenario_kind_resolves_fixture_replay():
    from repro.simulator.scenarios import TraceReplay, make_scenario
    sc = make_scenario("trace:azure", "sharegpt", 8.0)
    assert isinstance(sc, TraceReplay)
    assert sc.rate == pytest.approx(8.0)
    reqs = sc.generate(10.0)
    assert reqs and all(r.arrival_time < 10.0 for r in reqs)


def test_trace_scenario_tiles_past_the_excerpt_span():
    """A rate-normalized excerpt spans only (n-1)/rate seconds; scenario
    cells loop it so the whole experiment window carries trace-shaped
    traffic — no silent tail scoring vacuous phases."""
    from repro.simulator.scenarios import make_scenario
    sc = make_scenario("trace:azure", "sharegpt", 16.0)
    span = (len(sc.records) - 1) / 16.0
    duration = 4 * span
    reqs = sc.generate(duration)
    assert max(r.arrival_time for r in reqs) > 0.9 * duration
    # time-averaged rate carries across the tile seams
    assert len(reqs) / duration == pytest.approx(16.0, rel=0.05)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    # an un-looped replay of the same records keeps legacy semantics
    from repro.simulator.scenarios import TraceReplay
    flat = TraceReplay("flat", sc.records).generate(duration)
    assert max(r.arrival_time for r in flat) <= span + 1e-9


def test_trace_scenario_kind_rejects_unknown_fixture_and_kwargs():
    from repro.simulator.scenarios import make_scenario
    with pytest.raises(KeyError, match="fixture"):
        make_scenario("trace:nope", "sharegpt", 8.0)
    with pytest.raises(TypeError, match="no extra options"):
        make_scenario("trace:azure", "sharegpt", 8.0, burst=2.0)


def test_autoscale_axis_is_rejected_in_goodput_mode():
    with pytest.raises(ValueError, match="autoscale"):
        ExperimentRunner(mode="goodput", autoscale=("band",))
