"""Property tests for the arrival-process layer (hypothesis + seeded
fallbacks): every process must produce sorted arrivals inside
[0, duration), a realized count consistent with its integrated rate
(each shape is parameterized by its time-averaged rate, so the integral
of rate(t) over the horizon is rate * duration for all of them), and a
bit-identical stream under the same seed.  ``MixedScenario``'s merge
must be invariant under permutation of the tenant tuple — tenant streams
are seeded by identity, not position.
"""
import random

import numpy as np
import pytest

from repro.simulator.scenarios import (BurstyArrivals, DiurnalArrivals,
                                       MixedScenario, PoissonArrivals,
                                       RampArrivals, make_mixed_scenario)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # degrade to the seeded fallbacks below
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

PROCESS_KINDS = ("poisson", "bursty", "diurnal", "ramp")


def make_process(kind: str, rate: float):
    return {
        "poisson": PoissonArrivals,
        "bursty": BurstyArrivals,
        "diurnal": DiurnalArrivals,
        "ramp": RampArrivals,
    }[kind](rate)


# --------------------------------------------------------------------- #
# core properties, shared by the hypothesis and seeded drives
# --------------------------------------------------------------------- #
def check_sorted_in_range(kind: str, rate: float, seed: int,
                          duration: float = 300.0) -> None:
    times = make_process(kind, rate).sample(
        np.random.default_rng(seed), duration)
    assert np.all(np.diff(times) >= 0.0), f"{kind}: arrivals unsorted"
    if len(times):
        assert times[0] >= 0.0, f"{kind}: negative arrival"
        assert times[-1] < duration, f"{kind}: arrival past the horizon"


def check_seed_determinism(kind: str, rate: float, seed: int,
                           duration: float = 120.0) -> None:
    proc = make_process(kind, rate)
    a = proc.sample(np.random.default_rng(seed), duration)
    b = proc.sample(np.random.default_rng(seed), duration)
    assert np.array_equal(a, b), f"{kind}: same seed, different stream"
    c = proc.sample(np.random.default_rng(seed + 1), duration)
    if len(a) or len(c):   # distinct seeds should (generically) differ
        assert not np.array_equal(a, c), f"{kind}: seed ignored"


def check_count_matches_integrated_rate(kind: str, rate: float,
                                        seed: int) -> None:
    """Averaged over several independent streams so the bound is a CLT
    statement, not a single-draw lottery: each shape's time-averaged
    rate is ``rate`` by construction, hence the integrated rate over
    [0, T) is rate*T.  The duration is a whole number of diurnal
    periods so the sinusoid integrates out exactly."""
    duration, n_streams = 960.0, 8
    rng = np.random.default_rng(seed)
    counts = [len(make_process(kind, rate).sample(rng, duration))
              for _ in range(n_streams)]
    mean = float(np.mean(counts))
    # bursty carries phase-mix variance on top of Poisson noise
    rel_tol = 0.20 if kind == "bursty" else 0.10
    assert mean == pytest.approx(rate * duration, rel=rel_tol), \
        (kind, rate, mean)


def check_merge_permutation_stable(order, seed: int,
                                   duration: float = 60.0) -> None:
    base = make_mixed_scenario("poisson",
                               ["alpaca", "sharegpt", "longbench"],
                               9.0, seed=seed)
    tenants = tuple(base.tenants[i] for i in order)
    permuted = MixedScenario(base.name, tenants, seed=seed)
    want = [(r.arrival_time, r.prompt_len, r.output_len, r.slo_class)
            for r in base.generate(duration)]
    got = [(r.arrival_time, r.prompt_len, r.output_len, r.slo_class)
           for r in permuted.generate(duration)]
    assert want == got, "tenant permutation moved the merged stream"
    assert want == sorted(want, key=lambda t: t[0])


# --------------------------------------------------------------------- #
# hypothesis drives (fixed-seed profile via tests/conftest.py)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    KIND = st.sampled_from(PROCESS_KINDS)
    RATE = st.sampled_from([2.0, 6.0, 12.0])
    SEED = st.integers(0, 2**31 - 1)

    @needs_hypothesis
    @settings(max_examples=40, deadline=None)
    @given(kind=KIND, rate=RATE, seed=SEED)
    def test_arrivals_sorted_and_in_range(kind, rate, seed):
        check_sorted_in_range(kind, rate, seed)

    @needs_hypothesis
    @settings(max_examples=30, deadline=None)
    @given(kind=KIND, rate=RATE, seed=SEED)
    def test_same_seed_is_bit_identical(kind, rate, seed):
        check_seed_determinism(kind, rate, seed)

    @needs_hypothesis
    @settings(max_examples=12, deadline=None)
    @given(kind=KIND, rate=st.sampled_from([4.0, 10.0]), seed=SEED)
    def test_expected_count_matches_integrated_rate(kind, rate, seed):
        check_count_matches_integrated_rate(kind, rate, seed)

    @needs_hypothesis
    @settings(max_examples=20, deadline=None)
    @given(order=st.permutations(range(3)), seed=st.integers(0, 10_000))
    def test_mixed_merge_stable_under_tenant_permutation(order, seed):
        check_merge_permutation_stable(order, seed)


# --------------------------------------------------------------------- #
# seeded fallbacks (always run, hypothesis or not)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", PROCESS_KINDS)
def test_arrivals_sorted_and_in_range_seeded(kind):
    rng = random.Random(17)
    for _ in range(6):
        check_sorted_in_range(kind, rng.choice([2.0, 6.0, 12.0]),
                              rng.randrange(2**31))


@pytest.mark.parametrize("kind", PROCESS_KINDS)
def test_same_seed_is_bit_identical_seeded(kind):
    rng = random.Random(23)
    for _ in range(4):
        check_seed_determinism(kind, rng.choice([2.0, 6.0, 12.0]),
                               rng.randrange(2**31))


@pytest.mark.parametrize("kind", PROCESS_KINDS)
def test_expected_count_matches_integrated_rate_seeded(kind):
    rng = random.Random(31)
    for rate in (4.0, 10.0):
        check_count_matches_integrated_rate(kind, rate,
                                            rng.randrange(2**31))


@pytest.mark.parametrize("order", [(0, 1, 2), (2, 1, 0), (1, 2, 0)])
def test_mixed_merge_stable_under_tenant_permutation_seeded(order):
    for seed in (0, 7, 4242):
        check_merge_permutation_stable(order, seed)
