"""Unit layer for the flight recorder (``repro.obs``).

The contracts under test:

* ``NULL_TRACER`` is inert and allocation-free; every emission site
  guards on ``tracer.enabled`` so the off path costs one attribute read;
* the ``decision_log`` compat shim: attaching a list to
  ``engine.decision_log`` / ``system.decision_log`` keeps producing the
  exact legacy tuples (mirror-only tracer), detaching restores the
  null tracer;
* ``attach_tracer`` threads one tracer through engine, system,
  transport, macro scheduler, and macros;
* the JSONL codec round-trips every event; the Chrome-trace export
  renders one span per slot plus counters;
* per-(src,dst) link counters surface under ``Transport.summary()
  ["links"]`` and key-sum to the aggregate stats;
* TTFT attribution components sum exactly to each request's measured
  TTFT on a live engine run.
"""
import io
import json
from contextlib import redirect_stdout

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.slo import DATASET_SLOS
from repro.core.transport import Transport, TransportConfig
from repro.faults.network import NetworkModel
from repro.obs.events import (NULL_TRACER, NullTracer, Tracer,
                              attach_tracer, slot_rids)
from repro.obs.export import (SCHEMA, chrome_trace, read_jsonl, to_dicts,
                              write_jsonl)
from repro.obs.metrics import (attribution, instance_series, interference,
                               summarize, tpot_jitter)
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.engine import Link, SimulationEngine
from repro.simulator.runner import cell_seed
from repro.simulator.scenarios import make_scenario


def _cost():
    return InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20,
                             tp=4, pp=1)


def _traced_run(strategy="ecoserve", scenario="bursty", rate=6.0,
                duration=12.0, n_instances=4):
    seed = cell_seed(42, strategy, scenario, rate)
    system = make_system(strategy, _cost(), n_instances,
                         DATASET_SLOS["sharegpt"])
    reqs = make_scenario(scenario, "sharegpt", rate,
                         seed=seed).generate(duration)
    engine = SimulationEngine(system)
    trc = Tracer()
    attach_tracer(trc, engine=engine, system=system)
    engine.run(reqs, horizon=duration * 2.5)
    return trc, reqs, engine


# --------------------------------------------------------------------- #
# null tracer / guard contract
# --------------------------------------------------------------------- #
def test_null_tracer_is_inert_and_shared():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.events == ()
    assert NULL_TRACER.now() == -1.0
    # unguarded cold-path emissions must not crash or allocate events
    NULL_TRACER.slot(0.0, None, "prefill", 1.0, [], 0)
    NULL_TRACER.control(0.0, "decision", None)
    assert NULL_TRACER.events == ()
    assert isinstance(NULL_TRACER, NullTracer)


def test_default_wiring_is_null_everywhere():
    system = make_system("ecoserve", _cost(), 2, DATASET_SLOS["sharegpt"])
    engine = SimulationEngine(system)
    assert engine.tracer is NULL_TRACER
    assert system.tracer is NULL_TRACER
    assert system.transport.tracer is NULL_TRACER


def test_tracer_clock_fallback():
    trc = Tracer()
    assert trc.now() == -1.0
    trc.clock = lambda: 7.5
    assert trc.now() == 7.5


# --------------------------------------------------------------------- #
# decision_log compat shim
# --------------------------------------------------------------------- #
def test_decision_log_shim_produces_legacy_tuples():
    system = make_system("ecoserve", _cost(), 2, DATASET_SLOS["sharegpt"])
    engine = SimulationEngine(system)
    log = []
    engine.decision_log = log
    system.decision_log = log
    assert engine.decision_log is log
    # the shim-minted tracer is mirror-only: no events accumulate
    assert engine.tracer.enabled and engine.tracer.events == []
    seed = cell_seed(42, "ecoserve", "poisson", 4.0)
    reqs = make_scenario("poisson", "sharegpt", 4.0,
                         seed=seed).generate(6.0)
    engine.run(reqs, horizon=15.0)
    assert log, "decision log stayed empty"
    kinds = {e[0] for e in log}
    assert kinds <= {"slot", "admit", "queue", "drain"}
    assert all(isinstance(e, tuple) for e in log)
    slot = next(e for e in log if e[0] == "slot")
    assert len(slot) == 6 and isinstance(slot[5], tuple)  # legacy shape
    assert engine.tracer.events == []   # still mirror-only
    # detaching restores the null tracer
    engine.decision_log = None
    system.decision_log = None
    assert engine.tracer is NULL_TRACER
    assert system.tracer is NULL_TRACER


def test_decision_log_mirrors_through_live_tracer():
    """A run with BOTH a tracer and a decision_log: the log still gets
    the legacy tuples and the tracer records the full stream."""
    system = make_system("ecoserve", _cost(), 2, DATASET_SLOS["sharegpt"])
    engine = SimulationEngine(system)
    log = []
    engine.decision_log = log
    system.decision_log = log
    trc = Tracer()
    attach_tracer(trc, engine=engine, system=system)
    seed = cell_seed(42, "ecoserve", "poisson", 4.0)
    reqs = make_scenario("poisson", "sharegpt", 4.0,
                         seed=seed).generate(6.0)
    engine.run(reqs, horizon=15.0)
    assert log and trc.events
    n_slots = sum(1 for e in log if e[0] == "slot")
    assert n_slots == sum(1 for e in trc.events if e[0] == "slot")


# --------------------------------------------------------------------- #
# attach_tracer wiring
# --------------------------------------------------------------------- #
def test_attach_tracer_threads_the_whole_stack():
    system = make_system("ecoserve", _cost(), 2, DATASET_SLOS["sharegpt"])
    engine = SimulationEngine(system)
    trc = Tracer()
    attach_tracer(trc, engine=engine, system=system)
    assert engine.tracer is trc and system.tracer is trc
    assert system.transport.tracer is trc
    sched = getattr(system, "sched", None)
    if sched is not None:
        assert sched.tracer is trc
        assert all(m.tracer is trc for m in sched.macros)
    # the clock rides the engine
    assert trc.now() == engine.now


# --------------------------------------------------------------------- #
# event capture + analyses on a live run
# --------------------------------------------------------------------- #
def test_traced_run_captures_lifecycle_and_slots():
    trc, reqs, engine = _traced_run()
    kinds = {e[0] for e in trc.events}
    assert {"arrive", "admit", "slot", "finish"} <= kinds
    n_arrive = sum(1 for e in trc.events if e[0] == "arrive")
    assert n_arrive == len(reqs)
    n_finish = sum(1 for e in trc.events if e[0] == "finish")
    assert n_finish == len(engine.finished)


def test_attribution_components_sum_exactly_to_measured_ttft():
    trc, reqs, _ = _traced_run()
    attr = attribution(trc.events)
    rows = {r["rid"]: r for r in attr["rows"]}
    measured = [r for r in reqs if r.ttft is not None]
    assert measured and len(rows) == len(measured)
    for r in measured:
        row = rows[r.rid]
        # the decomposition telescopes: bit-exact per-row sum
        assert (row["queue_wait"] + row["prefill_wait"]
                + row["prefill_service"] + row["transfer"]) == row["ttft"]
        # and the events-derived TTFT matches the engine's measurement
        # (same floats up to association order of the telescoping sum)
        assert row["ttft"] == pytest.approx(r.ttft, abs=1e-9)
        assert row["queue_wait"] >= 0 and row["prefill_wait"] >= -1e-12


def test_instance_series_and_interference_shapes():
    trc, _, _ = _traced_run()
    series = instance_series(trc.events)
    assert series, "no per-instance series"
    for iid, s in series.items():
        n = len(s["t"])
        assert n > 0
        for k in ("kind", "dur", "batch", "kv_occupancy", "queue_depth",
                  "decode_batch_util", "prefill_backlog_tokens"):
            assert len(s[k]) == n
        assert all(0.0 <= v <= 1.0 + 1e-9 for v in s["kv_occupancy"])
        assert all(0.0 <= v <= 1.0 + 1e-9 for v in s["decode_batch_util"])
    inter = interference(trc.events)
    assert inter["n"] > 0
    assert inter["score"] >= 0.0
    assert inter["max"] >= inter["p99"] >= inter["p50"] >= 1.0 - 1e-9
    jit = tpot_jitter(trc.events)
    assert jit["n"] > 0 and jit["tpot_mean_p50"] > 0


def test_summarize_digest_is_json_safe_and_exact():
    trc, _, _ = _traced_run()
    digest = summarize(trc.events)
    json.dumps(digest)                      # JSON-safe end to end
    assert digest["attribution"]["exact"] is True
    assert digest["events"] == len(trc.events)
    assert digest["instances"] >= 1


# --------------------------------------------------------------------- #
# JSONL codec + Chrome-trace export
# --------------------------------------------------------------------- #
def test_jsonl_round_trip_is_lossless(tmp_path):
    trc, _, _ = _traced_run(duration=8.0)
    path = tmp_path / "run.trace.jsonl"
    trc.meta["name"] = "unit"
    n = write_jsonl(trc, path)
    assert n == len(trc.events)
    events, meta = read_jsonl(path)
    assert meta == {"name": "unit"}
    # live events may hold request batches; the named-field view is the
    # canonical equality domain
    assert to_dicts(events) == to_dicts(trc.events)
    # analyses agree between live and re-read events
    assert summarize(events) == summarize(trc.events)


def test_schema_covers_every_emitted_event_type():
    trc, _, _ = _traced_run(duration=8.0)
    assert {e[0] for e in trc.events} <= set(SCHEMA)
    for ev in trc.events:
        assert len(ev) == 2 + len(SCHEMA[ev[0]]), ev


def test_chrome_trace_renders_slots_and_counters():
    trc, _, _ = _traced_run(duration=8.0)
    doc = chrome_trace(trc.events, meta={"name": "unit"})
    evs = doc["traceEvents"]
    json.dumps(doc)
    n_slots = sum(1 for e in trc.events if e[0] == "slot")
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == n_slots
    assert {e["ph"] for e in evs} <= {"X", "C", "i", "M"}
    counters = {e["name"].split(" (")[0] for e in evs if e["ph"] == "C"}
    assert {"kv_occupancy", "queue_depth", "decode_batch_util",
            "prefill_backlog_tokens"} <= counters
    assert all(e["ts"] >= 0 and e.get("dur", 0) >= 0 for e in spans)


def test_slot_rids_normalizes_both_representations():
    class _R:
        def __init__(self, rid):
            self.rid = rid
    assert slot_rids([_R(3), _R(1)]) == (3, 1)
    assert slot_rids((3, 1)) == (3, 1)
    assert slot_rids([]) == ()


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def test_cli_summarize_attribution_export(tmp_path):
    from repro.obs.__main__ import main
    trc, _, _ = _traced_run(duration=8.0)
    path = tmp_path / "run.trace.jsonl"
    write_jsonl(trc, path)

    buf = io.StringIO()
    with redirect_stdout(buf):
        assert main(["summarize", str(path)]) == 0
    digest = json.loads(buf.getvalue())
    assert digest["attribution"]["exact"] is True

    buf = io.StringIO()
    with redirect_stdout(buf):
        assert main(["attribution", str(path), "--limit", "5"]) == 0
    assert "exact=True" in buf.getvalue()

    out = tmp_path / "run.perfetto.json"
    buf = io.StringIO()
    with redirect_stdout(buf):
        assert main(["export", str(path), "--perfetto",
                     "-o", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["traceEvents"]


# --------------------------------------------------------------------- #
# transport link counters
# --------------------------------------------------------------------- #
def _drain(engine):
    engine.drain()


def test_link_counters_key_sum_to_aggregate_stats():
    from tests.test_transport import _Engine, _lossy
    tr = Transport(TransportConfig(retries=2))
    tr.attach_network(_lossy(seed=1234, p=0.5))
    eng = _Engine()
    link = Link("nic", bandwidth=1e8, latency=1e-3)
    for i in range(40):
        tr.transfer(eng, i % 3, (i + 1) % 3, 1e5 * (1 + i % 7),
                    0.05 * i, deliver=lambda: None,
                    on_lost=lambda: None, link=link)
    eng.drain()
    s = tr.summary()
    links = s["links"]
    assert links, "degraded traffic must surface per-link rows"
    assert set(links) <= {"0->1", "1->2", "2->0"}
    for key in ("sent", "delivered", "lost", "retries", "timeouts"):
        assert sum(row[key] for row in links.values()) == s[key], key
    assert sum(r["sent"] for r in links.values()) == 40


def test_link_counters_flow_through_run_once_fault_summary():
    """End to end: a degraded FuDG cell's ``faults.transport.links``
    carries per-link rows (satellite contract)."""
    from repro.simulator.metrics import run_once

    def factory():
        return make_system("distserve", _cost(), 2,
                           DATASET_SLOS["sharegpt"])

    out = run_once(factory, make_scenario("poisson", "sharegpt", 3.0,
                                          seed=11),
                   3.0, DATASET_SLOS["sharegpt"], duration=10.0,
                   warmup=2.0, seed=11, faults="netdelay:40")
    links = out["faults"]["transport"]["links"]
    assert links and all("->" in k for k in links)
    assert sum(r["sent"] for r in links.values()) \
        == out["faults"]["transport"]["sent"] > 0


def test_transport_events_emitted_on_degraded_path():
    from tests.test_transport import _Engine, _lossy
    tr = Transport(TransportConfig(retries=1))
    tr.attach_network(_lossy(seed=7, p=1.0))
    trc = Tracer()
    tr.tracer = trc
    eng = _Engine()
    tr.transfer(eng, 0, 1, 1e5, 0.0, deliver=lambda: None,
                on_lost=lambda: None, link=Link("nic", 1e9, 1e-3))
    eng.drain()
    whats = [e[2] for e in trc.events if e[0] == "transport"]
    assert "send" in whats and "lost" in whats
    assert "retry" in whats
