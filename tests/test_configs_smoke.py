"""Per-architecture smoke tests: reduced variant, one forward/train step on
CPU, asserting output shapes and absence of NaNs; plus a prefill->decode
consistency check per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke_config
from repro.models import (forward, grow_cache, init_cache, init_params,
                          make_loss_fn)


def _smoke_batch(cfg, B=2, T=32, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {}
    if cfg.modality == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.frontend_dim)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        return batch, T
    if cfg.modality == "vision":
        Pn = cfg.num_patches
        T_text = T - Pn
        assert T_text > 1
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T_text)), jnp.int32)
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, Pn, cfg.frontend_dim)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T_text)), jnp.int32)
        return batch, T
    batch["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    return batch, T


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.num_layers >= 26
    assert cfg.param_count() > 1e9  # all assigned models are >=2B params


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_shapes(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.key(0), cfg)
    batch, T = _smoke_batch(cfg)
    logits, _ = jax.jit(
        lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, T, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.key(1), cfg)
    batch, _ = _smoke_batch(cfg)
    loss_fn = make_loss_fn(cfg)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(g))) for g in leaves)


@pytest.mark.parametrize(
    "arch", [a for a in ASSIGNED if not get_config(a).is_encoder])
def test_prefill_then_decode_matches_full_forward(arch):
    """Decode with cache must agree with teacher-forced full forward.

    MoE archs use a no-drop capacity factor here: capacity routing drops
    are a train-time approximation and would make the two modes diverge
    legitimately; with enough capacity the routing math must agree exactly.
    """
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    params = init_params(jax.random.key(2), cfg)
    B, T = 2, 24
    rng = np.random.default_rng(3)
    batch, _ = _smoke_batch(cfg, B=B, T=T, rng=rng)

    full_logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)

    if cfg.modality == "vision":
        prefill_batch = {"tokens": batch["tokens"][:, :-1],
                         "patches": batch["patches"]}
        last_tok = batch["tokens"][:, -1:]
    else:
        prefill_batch = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1:]

    _, cache = jax.jit(
        lambda p, b: forward(p, cfg, b, return_cache=True))(
        params, prefill_batch)
    cache = grow_cache(cfg, cache, T + 4)

    cache_len = jnp.full((B,), T - 1, jnp.int32)
    dec_batch = {"tokens": last_tok}
    if cfg.rope == "mrope":
        # text positions continue from the compressed patch grid (see
        # _default_positions): last text token sits at g + T_text - 1
        g = max(1, int(cfg.num_patches ** 0.5))
        t = jnp.full((B, 1, 3), g + batch["tokens"].shape[1] - 1, jnp.int32)
        dec_batch["positions"] = t
    dec_logits, _ = jax.jit(
        lambda p, b, c, cl: forward(p, cfg, b, cache=c, cache_len=cl))(
        params, dec_batch, cache, cache_len)

    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-2, atol=2e-2)


def test_long_context_ring_buffer_decode():
    """Sliding-window arch: decode far beyond the window stays finite and
    the ring holds exactly the trailing window."""
    cfg = get_smoke_config("llama3-8b-sw")
    params = init_params(jax.random.key(4), cfg)
    B = 1
    W = cfg.sliding_window
    cache = init_cache(cfg, B, max_len=4 * W)
    step = jax.jit(
        lambda p, b, c, cl: forward(p, cfg, b, cache=c, cache_len=cl))
    tok = jnp.ones((B, 1), jnp.int32)
    for pos in range(0, 3 * W, W // 2):
        cl = jnp.full((B,), pos, jnp.int32)
        logits, cache = step(params, {"tokens": tok}, cache, cl)
        assert np.all(np.isfinite(np.asarray(logits)))
