"""Real-execution serving: continuous batching engine + PaDG server on a
tiny model (CPU), and greedy-decoding equivalence with plain forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.request import Request
from repro.core.slo import SLO
from repro.models import forward, init_params
from repro.serving.calibration import CalibrationRecorder
from repro.serving.engine import (EngineConfig, MeasuredExecutor,
                                  ServingEngine)
from repro.serving.padg_server import PaDGServer
from repro.simulator.cost_model import FittedExecutor


def tiny_cfg():
    cfg = get_smoke_config("llama3-8b")
    return dataclasses.replace(cfg, num_layers=2, d_model=128, num_heads=2,
                               num_kv_heads=1, head_dim=64, d_ff=256,
                               vocab_size=300)


def greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced greedy decoding via repeated full forward."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = forward(params, cfg,
                            {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_full_forward_greedy():
    cfg = tiny_cfg()
    eng = ServingEngine(cfg, seed=3,
                        econf=EngineConfig(max_batch=2, max_seq_len=64,
                                           eos_token=-1))
    prompt = [5, 9, 17, 4, 33]
    n_new = 6
    want = greedy_reference(cfg, eng.params, prompt, n_new)

    req = Request(rid=0, arrival_time=0.0, prompt_len=len(prompt),
                  output_len=n_new, prompt_tokens=prompt)
    eng.prefill(req)
    while len(req.generated) < n_new:
        eng.decode_step()
    assert req.generated == want


def test_engine_concurrent_requests_isolated():
    """Two interleaved requests must produce the same tokens as served
    alone (KV-slot isolation under continuous batching)."""
    cfg = tiny_cfg()
    eng = ServingEngine(cfg, seed=4,
                        econf=EngineConfig(max_batch=2, max_seq_len=64,
                                           eos_token=-1))
    p1, p2 = [7, 3, 11], [21, 9, 2, 40, 8]
    solo1 = greedy_reference(cfg, eng.params, p1, 5)
    solo2 = greedy_reference(cfg, eng.params, p2, 5)

    r1 = Request(rid=1, arrival_time=0, prompt_len=len(p1), output_len=5,
                 prompt_tokens=p1)
    r2 = Request(rid=2, arrival_time=0, prompt_len=len(p2), output_len=5,
                 prompt_tokens=p2)
    eng.prefill(r1)
    eng.decode_step()          # r1 advances alone
    eng.prefill(r2)            # r2 joins mid-flight
    for _ in range(6):
        eng.decode_step()
    assert r1.generated[:5] == solo1
    assert r2.generated[:5] == solo2


@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-3b"])
def test_padg_server_end_to_end(arch):
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                              num_heads=2, num_kv_heads=max(1, min(
                                  2, cfg.num_kv_heads)), head_dim=64,
                              d_ff=256, vocab_size=300)
    slo = SLO(ttft=60.0, tpot=10.0)   # wall-clock CPU: loose SLOs
    server = PaDGServer(cfg, n_instances=2, slo=slo,
                        econf=EngineConfig(max_batch=2, max_seq_len=48,
                                           eos_token=-1))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(3, 10))
        reqs.append(Request(
            rid=i, arrival_time=0.02 * i, prompt_len=plen, output_len=4,
            prompt_tokens=[int(x) for x in rng.integers(2, 290, plen)]))
    stats = server.serve(reqs)
    s = stats.summary()
    assert s["finished"] == 6
    for r in stats.finished:
        assert len(r.generated) == 4
        assert r.finish_time >= r.first_token_time >= 0
    server.shutdown()


# --------------------------------------------------------------------- #
# MeasuredExecutor: shape-aware predictions
# --------------------------------------------------------------------- #
def test_measured_executor_seeds_from_model_probes():
    """Seeded from an exactly-linear model, the probe-derived constants
    reproduce the model's predictions before any observation."""
    seed = FittedExecutor(prefill_base=2e-3, prefill_per_token=3e-4,
                          decode_base=1e-3, decode_per_seq=4e-4,
                          decode_per_ctx_token=2e-6)
    ex = MeasuredExecutor(seed_model=seed)
    for n in (1, 17, 400):
        assert ex.prefill_time([n]) == pytest.approx(seed.prefill_time([n]))
    assert ex.decode_time(3, ctx_sum=500) == pytest.approx(
        seed.decode_time(3, ctx_sum=500))


def test_measured_executor_decode_shape_aware():
    """decode_time must grow with batch AND with context — the flat EWMA
    regression this replaces predicted one constant for every shape."""
    ex = MeasuredExecutor(seed_model=FittedExecutor(
        decode_base=1e-3, decode_per_seq=4e-4, decode_per_ctx_token=2e-6))
    assert ex.decode_time(0) == 0.0
    assert ex.decode_time(4) > ex.decode_time(2) > ex.decode_time(1)
    assert (ex.decode_time(2, ctx_sum=4096) > ex.decode_time(2, ctx_sum=64)
            > ex.decode_time(2, ctx_sum=0))
    # observations rescale, but never flatten, the shape dependence
    for _ in range(20):
        ex.observe_decode(5e-3, batch=2, ctx_sum=64)
    assert ex.decode_time(4, ctx_sum=128) > ex.decode_time(2, ctx_sum=64)


def test_measured_executor_legacy_fallbacks():
    """Without a model to probe, the documented flat fallbacks apply."""
    ex = MeasuredExecutor()
    assert ex.prefill_time([10]) == pytest.approx(10 * 2e-4)
    assert ex.decode_time(3) == pytest.approx(3 * 5e-2)
    ex = MeasuredExecutor(fallback_prefill=1e-3, fallback_decode=1e-2)
    assert ex.prefill_time([4]) == pytest.approx(4e-3)
    assert ex.decode_time(2) == pytest.approx(2e-2)


def test_engine_recorder_captures_op_shapes():
    cfg = tiny_cfg()
    rec = CalibrationRecorder()
    eng = ServingEngine(cfg, seed=5, recorder=rec,
                        econf=EngineConfig(max_batch=2, max_seq_len=64,
                                           eos_token=-1))
    prompt = [5, 9, 17, 4]
    req = Request(rid=0, arrival_time=0.0, prompt_len=len(prompt),
                  output_len=3, prompt_tokens=prompt)
    eng.prefill(req)
    while len(req.generated) < 3:
        eng.decode_step()
    assert [toks for toks, _ in rec.prefill] == [len(prompt)]
    assert len(rec.decode) >= 2
    for batch, ctx_sum, dt in rec.decode:
        assert batch == 1 and ctx_sum >= len(prompt) and dt > 0.0
    for _, dt in rec.prefill:
        assert dt > 0.0
