"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles,
sweeping shapes and dtypes (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

RNG = np.random.default_rng(42)


def rand(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------- #
# flash prefill
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,S,Hq,Hkv,D", [
    (1, 128, 128, 4, 4, 64),       # MHA square
    (2, 128, 128, 8, 2, 64),       # GQA 4:1
    (1, 96, 96, 4, 1, 128),        # MQA, non-multiple T
    (2, 256, 256, 10, 2, 128),     # G=5 odd grouping
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_matches_ref(B, T, S, Hq, Hkv, D, dtype):
    q = rand(B, T, Hq, D, dtype=dtype)
    k = rand(B, S, Hkv, D, dtype=dtype)
    v = rand(B, S, Hkv, D, dtype=dtype)
    out = flash_prefill(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_prefill_sliding_window(window):
    B, T, Hq, Hkv, D = 1, 160, 4, 2, 64
    q, k, v = rand(B, T, Hq, D), rand(B, T, Hkv, D), rand(B, T, Hkv, D)
    out = flash_prefill(q, k, v, causal=True, window=window,
                        block_q=64, block_k=64, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_chunked_offset():
    """Chunked prefill: queries at offset attend to the kv prefix."""
    B, Hq, Hkv, D = 1, 4, 2, 64
    S, chunk, off = 192, 64, 128
    q = rand(B, chunk, Hq, D)
    k, v = rand(B, S, Hkv, D), rand(B, S, Hkv, D)
    out = flash_prefill(q, k, v, causal=True, q_offset=off,
                        block_q=32, block_k=64, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_prefill_encoder_bidirectional():
    B, T, H, D = 1, 128, 4, 64
    q, k, v = rand(B, T, H, D), rand(B, T, H, D), rand(B, T, H, D)
    out = flash_prefill(q, k, v, causal=False, block_q=64, block_k=64,
                        interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------- #
# decode attention
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,S,Hq,Hkv,D,block_s", [
    (2, 256, 8, 2, 64, 64),
    (4, 1000, 4, 4, 128, 256),     # ragged, non-multiple S
    (1, 512, 10, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, S, Hq, Hkv, D, block_s, dtype):
    q = rand(B, Hq, D, dtype=dtype)
    kc = rand(B, S, Hkv, D, dtype=dtype)
    vc = rand(B, S, Hkv, D, dtype=dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, kc, vc, lengths, block_s=block_s,
                           interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------- #
# RG-LRU scan
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,d,bt,bd", [
    (2, 64, 128, 32, 64),
    (1, 100, 256, 64, 128),        # non-multiple T
    (3, 32, 96, 32, 128),          # non-multiple d
])
def test_rglru_scan_matches_ref(B, T, d, bt, bd):
    log_a = -jnp.abs(rand(B, T, d)) * 0.1
    b = rand(B, T, d) * 0.3
    h0 = rand(B, d)
    out = rglru_scan(log_a, b, h0, block_t=bt, block_d=bd, interpret=True)
    want = ref.rglru_scan_ref(log_a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_rglru_matches_model_layer_scan():
    """Kernel agrees with the associative-scan used inside the model."""
    from repro.models.layers import rglru_scan_jnp
    B, T, d = 2, 48, 64
    log_a = -jnp.abs(rand(B, T, d)) * 0.2
    b = rand(B, T, d)
    out_kernel = rglru_scan(log_a, b, block_t=16, block_d=64, interpret=True)
    out_model = rglru_scan_jnp(log_a, b)
    np.testing.assert_allclose(np.asarray(out_kernel),
                               np.asarray(out_model), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------- #
# RWKV6 scan
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,H,D,bt", [
    (1, 64, 2, 64, 16),
    (2, 96, 4, 32, 32),            # non-multiple T
])
def test_rwkv6_scan_matches_ref(B, T, H, D, bt):
    r = rand(B, T, H, D) * 0.5
    k = rand(B, T, H, D) * 0.5
    v = rand(B, T, H, D) * 0.5
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (B, T, H, D)), jnp.float32)
    u = rand(H, D) * 0.1
    out = rwkv6_scan(r, k, v, w, u, block_t=bt, interpret=True)
    want, _ = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_rwkv6_kernel_matches_model_chunked():
    from repro.models.layers import rwkv6_chunked_jnp
    B, T, H, D = 1, 80, 2, 32
    r, k, v = rand(B, T, H, D), rand(B, T, H, D), rand(B, T, H, D)
    w = jnp.asarray(RNG.uniform(0.7, 0.999, (B, T, H, D)), jnp.float32)
    u = rand(H, D) * 0.1
    out_kernel = rwkv6_scan(r, k, v, w, u, block_t=32, interpret=True)
    out_model, _ = rwkv6_chunked_jnp(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(out_kernel),
                               np.asarray(out_model), rtol=1e-4, atol=1e-4)
