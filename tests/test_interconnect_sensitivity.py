"""Deterministic regression layer for the interconnect-sensitivity grid.

``tests/golden/interconnect_sensitivity.json`` pins the commodity-link
degradation sweep bit-exactly — EcoServe, vLLM (NoDG), DistServe, and
MoonCake on the bursty shape over five network grades expressed in the
PR 7 fault grammar — including each degraded cell's transport counters.
Regenerate (after an *intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_interconnect_sensitivity \
        --write-golden
"""
import json
import pathlib

import pytest

from repro.simulator.runner import ExperimentRunner, interconnect_runner

GOLDEN = (pathlib.Path(__file__).parent / "golden"
          / "interconnect_sensitivity.json")

FUDG = ("distserve", "mooncake")
HOLDERS = ("ecoserve", "vllm")


def _golden():
    return ExperimentRunner.load(GOLDEN)


def _grades(meta):
    return ["none" if f is None else f for f in meta["faults"]]


def _pmins(golden, strat):
    grid = ExperimentRunner.grid(golden)
    meta = golden["meta"]
    scen, rate = meta["scenarios"][0], meta["rates"][0]
    return [grid[strat][scen][g][rate]["attainment_phase_min"]
            for g in _grades(meta)]


# --------------------------------------------------------------------- #
# golden reproduction across worker counts: network-fault schedules and
# every transport draw are seeded per cell, so the grid must land
# identically no matter how the pool interleaves the cells
# --------------------------------------------------------------------- #
def test_interconnect_golden_reproduced_bit_exactly():
    golden = _golden()
    fresh = interconnect_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "interconnect grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "interconnect grid no longer reproduces the golden metrics "
        "(attainment, injector log, or transport counters moved); if "
        "intentional, regenerate with `python -m "
        "benchmarks.bench_interconnect_sensitivity --write-golden` and "
        "review the diff")


@pytest.mark.parametrize("n_workers", [1, 3])
def test_degraded_cells_worker_count_invariant(n_workers):
    """The headline degraded FuDG cells, re-run under different worker
    counts, must equal the golden cells byte for byte (cell seeds,
    fault-schedule seeds, and every per-message transport draw depend
    only on the cell spec, never on scheduling order)."""
    golden = _golden()
    base = interconnect_runner()
    worst = base.faults[-1]
    runner = ExperimentRunner(
        strategies=FUDG, scenarios=base.scenarios, rates=base.rates,
        faults=(worst,), phases=base.phases, model=base.model,
        hw=base.hw, tp=base.tp, pp=base.pp,
        n_instances=base.n_instances, workload=base.workload,
        duration=base.duration, warmup=base.warmup,
        base_seed=base.base_seed, n_workers=n_workers)
    fresh = runner.run()["cells"]
    for cell in fresh:
        want = next(c for c in golden["cells"]
                    if c["strategy"] == cell["strategy"]
                    and c["faults"] == worst)
        assert json.dumps(cell, sort_keys=True) == \
            json.dumps(want, sort_keys=True), (
                f"{cell['strategy']} degraded cell is not bit-exact at "
                f"n_workers={n_workers}")


def test_interconnect_golden_covers_the_axes():
    golden = _golden()
    cells = golden["cells"]
    assert {c["strategy"] for c in cells} == set(FUDG) | set(HOLDERS)
    grades = golden["meta"]["faults"]
    assert grades[0] is None and len(grades) == 5
    assert all("net" in g for g in grades[1:])
    # the faults axis is seed-neutral: within a strategy every grade
    # replays the identical arrival sequence, so the attainment delta
    # isolates the interconnect
    by_strat = {}
    for c in cells:
        by_strat.setdefault(c["strategy"], set()).add(c["seed"])
    for strat, seeds in by_strat.items():
        assert len(seeds) == 1, (strat, seeds)


# --------------------------------------------------------------------- #
# the headline claims, pinned in the golden so they cannot silently rot
# --------------------------------------------------------------------- #
def test_fudg_attainment_tracks_the_fabric():
    """ISSUE acceptance: both FuDG baselines' min-phase attainment is
    monotonically non-increasing across the degradation grades and
    collapses to zero at the worst one — every request's KV cache
    crosses the degraded link between prefill and decode."""
    golden = _golden()
    for strat in FUDG:
        pmins = _pmins(golden, strat)
        assert pmins[0] > 0.9, (strat, pmins)
        for a, b in zip(pmins, pmins[1:]):
            assert b <= a + 1e-12, (strat, pmins)
        assert pmins[-1] == 0.0, (strat, pmins)


def test_ecoserve_and_nodg_hold_the_clean_link_frontier():
    """ISSUE acceptance: EcoServe and the NoDG baseline keep all phases
    on one instance, so their min-phase attainment stays within 10% of
    the clean-link value at every grade."""
    golden = _golden()
    for strat in HOLDERS:
        pmins = _pmins(golden, strat)
        clean = pmins[0]
        assert clean > 0.8, (strat, pmins)
        for p in pmins:
            assert p >= 0.9 * clean, (strat, pmins)


def test_transport_accounting_pins_the_structural_reason():
    """Degraded FuDG cells show real KV traffic (sent > 0) with
    retry/timeout churn at the lossy grades; EcoServe/NoDG cells show
    zero transfers — they have nothing on the wire to lose.  Clean
    cells carry no fault key at all, and no degraded cell ever invents
    new ``fault_stats`` keys (network events live in the transport
    counters only)."""
    golden = _golden()
    worst = golden["meta"]["faults"][-1]
    for cell in golden["cells"]:
        m = cell["metrics"]
        if cell["faults"] is None:
            assert "faults" not in m
            continue
        f = m["faults"]
        assert set(f["applied"]) <= {"netdelay", "netloss", "netdegrade",
                                     "partition"}
        assert "stats" not in f or not any(
            k.startswith("net") for k in f.get("stats", {}))
        tr = f["transport"]
        assert tr["delivered"] + tr["lost"] == tr["sent"]
        if cell["strategy"] in HOLDERS:
            assert tr["sent"] == 0, (cell["strategy"], cell["faults"])
        else:
            assert tr["sent"] > 0, (cell["strategy"], cell["faults"])
            if cell["faults"] == worst:
                assert tr["retries"] > 0 or tr["lost"] > 0, \
                    (cell["strategy"], tr)


def test_network_grades_parse_and_injector_applies_them():
    """Every non-clean grade in the golden round-trips through the
    fault-spec parser, and its injector log shows each clause applied
    exactly once at t=0 (whole-run episodes)."""
    from repro.faults import make_fault_schedule
    golden = _golden()
    for grade in golden["meta"]["faults"][1:]:
        sched = make_fault_schedule(grade, seed=123, duration=48.0)
        assert all(e.kind.startswith("net") or e.kind == "partition"
                   for e in sched.events)
    for cell in golden["cells"]:
        if cell["faults"] is None:
            continue
        f = cell["metrics"]["faults"]
        n_clauses = len(cell["faults"].split(";"))
        assert sum(f["applied"].values()) == n_clauses
        assert all(e["t"] == 0.0 for e in f["log"])
