"""Golden regression layer for the fleet subsystem.

``tests/golden/fleet_grid.json`` pins the canonical routing x
rebalancing grid bit-exactly AND the paper-level ordering claims it
demonstrates: under a mid-run mix shift, budget-constrained rebalancing
strictly beats the static partition on min-over-pools attainment under
every routing policy, and quality-tiered spillover lifts the static
floor above pinned routing's before any capacity moves.  Regenerate
(after an *intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_fleet --write-golden
"""
import json
import pathlib

from repro.simulator.runner import ExperimentRunner, fleet_grid_runner

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fleet_grid.json"


def _grid(results):
    meta = results["meta"]
    return (ExperimentRunner.grid(results), meta["strategies"],
            meta["scenarios"][0], meta["rates"][0])


def test_fleet_golden_grid_reproduced_bit_exactly():
    golden = ExperimentRunner.load(GOLDEN)
    fresh = fleet_grid_runner(n_workers=2).run()
    assert not fresh.get("errors"), fresh.get("errors")
    assert fresh["meta"] == golden["meta"], \
        "fleet grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "fleet grid no longer reproduces the golden metrics; if the "
        "change is intentional, regenerate with `python -m "
        "benchmarks.bench_fleet --write-golden` and review the diff")


def test_fleet_cells_share_one_seed_and_cover_the_grid():
    golden = ExperimentRunner.load(GOLDEN)
    cells = golden["cells"]
    assert len(cells) == 6
    assert len({c["seed"] for c in cells}) == 1, (
        "fleet cells must replay identical arrivals across routers and "
        "control levels")
    assert {c["strategy"] for c in cells} == \
        {"pinned", "cheapest-feasible", "quality-tiered"}
    assert {c.get("autoscale") for c in cells} == {None, "rebalance"}
    for c in cells:
        assert [p["name"] for p in c["system"]["pools"]] == ["chat", "code"]


def test_rebalancing_strictly_beats_static_partition_in_golden():
    grid, routers, scen, rate = _grid(ExperimentRunner.load(GOLDEN))
    floors = {}
    for router in routers:
        static = grid[router][scen]["static"][rate]["attainment_pool_min"]
        rebal = grid[router][scen]["rebalance"][rate]["attainment_pool_min"]
        floors[router] = static
        assert rebal > static, (
            f"{router}: rebalanced min-over-pools attainment "
            f"{rebal:.4f} must strictly beat the static partition's "
            f"{static:.4f}")
    # routing alone also helps: spillover lifts the static floor
    assert floors["quality-tiered"] > floors["pinned"]


def test_quality_tiered_golden_cells_actually_spill():
    grid, _, scen, rate = _grid(ExperimentRunner.load(GOLDEN))
    pinned = grid["pinned"][scen]["static"][rate]["fleet"]["routed"]
    tiered = grid["quality-tiered"][scen]["static"][rate]["fleet"]["routed"]
    assert sum(pinned.values()) == sum(tiered.values()), (
        "identical arrivals must reach both routers")
    assert tiered["chat"] > pinned["chat"], (
        "quality-tiered routing never spilled the surging tenant "
        "up-tier into the chat pool")


def test_golden_trajectories_honor_budget_and_floor():
    golden = ExperimentRunner.load(GOLDEN)
    rebalanced = [c for c in golden["cells"] if c.get("autoscale")]
    assert rebalanced, "golden grid lost its rebalanced cells"
    for cell in rebalanced:
        tl = cell["metrics"]["timeline"]
        devices = {p["name"]: p["devices_per_instance"]
                   for p in cell["system"]["pools"]}
        trajs = {name: ptl["trajectory"]
                 for name, ptl in tl["per_pool"].items()}
        assert {len(t) for t in trajs.values()} != set(), \
            "rebalanced cell recorded no trajectory"
        for i in range(min(len(t) for t in trajs.values())):
            committed = sum(trajs[n][i]["n_target"] * devices[n]
                            for n in trajs)
            assert committed <= tl["budget"]
            assert all(trajs[n][i]["n_target"] >= 1 for n in trajs)
        # the rebalancer actually acted on the shift in every cell
        assert tl["n_ups"] + tl["n_moves"] + tl["n_downs"] > 0
