"""Hypothesis property tests for the Pallas kernels (interpret mode):
random shapes/block sizes must match the oracles, and the serving-path
invariant (decode-over-cache == last prefill row) must hold."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.rglru_scan import rglru_scan

RNG = np.random.default_rng(7)


def rand(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(8, 96),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 3]),
    d=st.sampled_from([32, 64]),
    bq=st.sampled_from([16, 32]),
    bk=st.sampled_from([16, 64]),
)
def test_flash_prefill_random_shapes(t, hkv, g, d, bq, bk):
    q = rand(1, t, hkv * g, d)
    k = rand(1, t, hkv, d)
    v = rand(1, t, hkv, d)
    out = flash_prefill(q, k, v, causal=True, block_q=bq, block_k=bk,
                        interpret=True)
    want = ref.flash_prefill_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(16, 300),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 4]),
    bs=st.sampled_from([32, 128]),
    data=st.data(),
)
def test_decode_attention_random_lengths(s, hkv, g, bs, data):
    B, D = 2, 64
    lengths = jnp.asarray(
        [data.draw(st.integers(1, s)) for _ in range(B)], jnp.int32)
    q = rand(B, hkv * g, D)
    kc, vc = rand(B, s, hkv, D), rand(B, s, hkv, D)
    out = decode_attention(q, kc, vc, lengths, block_s=bs, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_decode_equals_prefill_last_position():
    """Decoding the (T)th token against a T-entry cache equals row T of a
    (T+1)-long prefill — the serving-path consistency invariant."""
    T, Hkv, G, D = 33, 2, 2, 64
    q_full = rand(1, T + 1, Hkv * G, D)
    k_full = rand(1, T + 1, Hkv, D)
    v_full = rand(1, T + 1, Hkv, D)
    full = ref.flash_prefill_ref(q_full, k_full, v_full, causal=True)
    out = decode_attention(q_full[:, -1], k_full, v_full,
                           jnp.asarray([T + 1], jnp.int32),
                           block_s=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(full[0, -1]),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(4, 80),
    d=st.sampled_from([32, 96]),
    bt=st.sampled_from([8, 32]),
    bd=st.sampled_from([32, 64]),
)
def test_rglru_random_shapes(t, d, bt, bd):
    la = -jnp.abs(rand(1, t, d)) * 0.2
    b = rand(1, t, d) * 0.5
    out = rglru_scan(la, b, block_t=bt, block_d=bd, interpret=True)
    want = ref.rglru_scan_ref(la, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
